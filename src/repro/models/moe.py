"""Mixture-of-Experts layer: top-k router + capacity-bounded grouped GEMM.

TPU-native design: instead of a per-token gather loop (GPU style), tokens
are sorted by expert id and packed into a fixed-capacity ``[E, C, d]``
buffer, experts run as one batched matmul on the MXU, and outputs are
scattered back weighted by router probabilities. All shapes are static;
tokens beyond an expert's capacity are dropped (standard Switch/GShard
semantics, capacity_factor configurable).

Sharding: the E axis is expert-parallel over the ``model`` mesh axis when
E divides it; otherwise (e.g. Mixtral's 8 experts on a 16-wide axis) the
expert FFN hidden dim is tensor-parallel instead. See repro.sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, ModelConfig, dense_init, maybe_shard


def init_moe_params(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (d, E), jnp.float32),  # router in fp32
        "w1": dense_init(ks[1], d, (E, d, f), cfg.param_dtype),
        "w3": dense_init(ks[2], d, (E, d, f), cfg.param_dtype),
        "w2": dense_init(ks[3], f, (E, f, d), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared_w1"] = dense_init(k1, d, (d, fs), cfg.param_dtype)
        p["shared_w3"] = dense_init(k2, d, (d, fs), cfg.param_dtype)
        p["shared_w2"] = dense_init(k3, fs, (fs, d), cfg.param_dtype)
    return p


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    # round up to a multiple of 64 so the capacity dim stays shardable over
    # the (pod×data) batch axes on both production meshes (and MXU-aligned)
    mult = 64 if n_tokens >= 4096 else 8
    return max(8, -(-c // mult) * mult)


def _pick_groups(T: int) -> int:
    """Dispatch groups = number of data shards the token dim can carry
    (32 covers pod×data on the multi-pod mesh; falls back gracefully)."""
    for g in (32, 16, 8, 4, 2):
        if T % g == 0 and T // g >= 2:
            return g
    return 1


def moe_ffn(params, x, cfg: ModelConfig):
    """x: [B, S, d] -> ([B, S, d], aux) where aux has router stats.

    Dispatch is adaptive: the GShard-style grouped path wins on big token
    counts (local scatter, clean all-to-all) but its per-group minimum
    capacity multiplies padding when assignments-per-expert are few
    (decode shapes) — there the flat global buffer is strictly smaller.
    """
    T = x.shape[0] * x.shape[1]
    grouped_ok = (T * cfg.top_k) / max(cfg.n_experts, 1) >= 64
    if cfg.moe_dispatch == "grouped" and grouped_ok and _pick_groups(T) > 1:
        return moe_ffn_grouped(params, x, cfg)
    return moe_ffn_flat(params, x, cfg)


def moe_ffn_grouped(params, x, cfg: ModelConfig):
    """GShard-style grouped dispatch: tokens are packed into per-group
    capacity buffers where each group lives on one data shard, so the
    scatter/gather is rank-local; the group→expert transpose happens inside
    one einsum whose operands GSPMD turns into a clean all-to-all.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = _pick_groups(T)
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    xt = maybe_shard(xt, BATCH_AXES, None, None)

    logits = (xt.astype(jnp.float32) @ params["router"])  # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                   # [G, Tg, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    C = expert_capacity(Tg, cfg)

    def pack(idx_g):
        """Per-group slot assignment. idx_g: [Tg, K] -> dest, keep, token."""
        flat_e = idx_g.reshape(-1)                        # [Tg*K]
        sort = jnp.argsort(flat_e)
        sorted_e = flat_e[sort]
        rank = jnp.arange(Tg * K) - jnp.searchsorted(sorted_e, sorted_e,
                                                     side="left")
        keep = rank < C
        dest = jnp.where(keep, sorted_e * C + rank, E * C)
        return dest, keep, sort // K, sort

    dest, keep, token, sort = jax.vmap(pack)(idx)         # all [G, Tg*K]

    def scatter_group(x_g, dest_g, token_g):
        return jnp.zeros((E * C, d), x.dtype).at[dest_g].set(
            x_g[token_g], mode="drop")

    buf = jax.vmap(scatter_group)(xt, dest, token)        # [G, E*C, d]
    buf = buf.reshape(G, E, C, d)
    buf = maybe_shard(buf, BATCH_AXES, "model", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, params["w3"])
    h = maybe_shard(h, BATCH_AXES, "model", None, None) if E % 16 == 0 else \
        maybe_shard(h, BATCH_AXES, None, None, "model")
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w2"])
    out_buf = maybe_shard(out_buf, BATCH_AXES, "model", None, None)
    out_buf = out_buf.reshape(G, E * C, d)

    def combine_group(out_g, dest_g, token_g, gate_g, keep_g, sort_g):
        gathered = out_g.at[dest_g].get(mode="fill", fill_value=0)
        w = (gate_g.reshape(-1)[sort_g] * keep_g.astype(jnp.float32))[:, None]
        return jnp.zeros((Tg, d), x.dtype).at[token_g].add(
            (gathered * w.astype(out_g.dtype)))

    y = jax.vmap(combine_group)(out_buf, dest, token, gate, keep, sort)
    y = maybe_shard(y, BATCH_AXES, None, None)

    if cfg.n_shared_experts:
        xt2 = xt.reshape(T, d)
        hs = jax.nn.silu(xt2 @ params["shared_w1"]) * (xt2 @ params["shared_w3"])
        y = y.reshape(T, d) + hs @ params["shared_w2"]

    me = probs.mean((0, 1))
    ce = jnp.zeros((E,)).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "dropped": 1.0 - keep.mean()}
    return y.reshape(B, S, d), aux


def moe_ffn_flat(params, x, cfg: ModelConfig):
    """Single global capacity buffer (naive baseline for §Perf)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate, idx = jax.lax.top_k(probs, K)      # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- pack assignments into the [E, C, d] buffer ------------------
    C = expert_capacity(T, cfg)
    flat_e = idx.reshape(-1)                          # [T*K]
    sort = jnp.argsort(flat_e)                        # stable
    sorted_e = flat_e[sort]
    # rank of each assignment within its expert group
    rank = jnp.arange(T * K) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # E*C = drop slot
    token = sort // K                                  # originating token

    buf = jnp.zeros((E * C, d), x.dtype).at[dest].set(xt[token], mode="drop")
    buf = buf.reshape(E, C, d)
    # expert-parallel on the model axis when E divides it (all-to-all
    # dispatch); otherwise the expert hidden dim is tensor-parallel
    # (Mixtral case). The packed capacity dim is ALWAYS data-parallel —
    # without this GSPMD replicates expert compute across the batch axes
    # (verified: per-device MoE flops dropped ~16× when pinned).
    buf = maybe_shard(buf, "model", BATCH_AXES, None)

    # ---- expert compute: batched SwiGLU ------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    h = maybe_shard(h, "model", BATCH_AXES, None) if E % 16 == 0 else \
        maybe_shard(h, None, BATCH_AXES, "model")
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w2"]).reshape(E * C, d)

    # ---- combine back -------------------------------------------------
    gathered = out_buf.at[dest].get(mode="fill", fill_value=0)  # [T*K, d]
    # gate/keep must be aligned with the sorted assignment order
    gate_sorted = gate.reshape(-1)[sort]
    w = (gate_sorted * keep.astype(gate.dtype))[:, None]
    y = jnp.zeros((T, d), x.dtype).at[token].add((gathered * w).astype(x.dtype))

    if cfg.n_shared_experts:
        hs = jax.nn.silu(xt @ params["shared_w1"]) * (xt @ params["shared_w3"])
        y = y + hs @ params["shared_w2"]

    # load-balance auxiliaries (Switch-style)
    me = probs.mean(0)                                # mean router prob per expert
    ce = jnp.zeros((E,)).at[flat_e].add(1.0) / (T * K)  # fraction routed
    aux = {"lb_loss": E * jnp.sum(me * ce), "dropped": 1.0 - keep.mean()}
    return y.reshape(B, S, d), aux
