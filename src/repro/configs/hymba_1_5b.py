"""hymba-1.5b [hybrid] — parallel attention + mamba heads. [arXiv:2411.13676]

25 query heads / 5 kv heads are padded to 32/8 physical (masked) for
shardability. Attention branch uses sliding-window attention (Hymba uses
SWA in most layers); the SSM branch runs a selective scan with state 16.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="dense", hybrid=True,
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, d_head=64, ssm_state=16,
        n_heads_padded=32, n_kv_heads_padded=8,
        attn_variant="swa", window=1024,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        source="arXiv:2411.13676",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64, ssm_state=8, window=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=4, n_kv_heads_padded=2,
    )
