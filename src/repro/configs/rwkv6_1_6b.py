"""rwkv6-1.6b [ssm] — Finch, data-dependent decay linear attention,
attention-free. [arXiv:2404.05892]

32 RWKV heads of size 64 (d_model 2048); channel mix hidden 7168.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab=65536, d_head=64,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        source="arXiv:2404.05892",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
