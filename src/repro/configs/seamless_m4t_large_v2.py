"""seamless-m4t-large-v2 [audio] — enc-dec multimodal. [arXiv:2308.11596]

Transformer backbone only: 24-layer local-attention encoder consuming
precomputed audio-frame embeddings (the mel+conv frontend is stubbed per
the assignment carve-out) and a 24-layer causal decoder with cross
attention. 16 heads, kv=16 (MHA), d=1024, ff=8192, vocab 256206.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, encoder_layers=24, encoder_window=1024,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, d_head=64,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=10000.0,
        source="arXiv:2308.11596",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, encoder_layers=2, encoder_window=32,
        d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, vocab_padded=0, d_head=32,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
