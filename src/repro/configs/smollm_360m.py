"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]

15 query heads / 5 kv heads padded to 16/8 physical (masked) for the
16-wide model axis.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, d_head=64,
        n_heads_padded=16, n_kv_heads_padded=8,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=10000.0,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=3, n_kv_heads=1,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=4, n_kv_heads_padded=1,
    )
