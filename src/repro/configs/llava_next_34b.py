"""llava-next-34b [vlm] — anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

Language backbone only: the SigLIP/ViT vision tower + projector is stubbed —
``input_specs`` supplies 2880 precomputed patch embeddings (anyres: 4 tiles +
1 base image × 576 patches) prepended to the text tokens. 56 query heads are
padded to 64 physical (masked) for the 16-wide model axis.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000, d_head=128,
        n_heads_padded=64, n_kv_heads_padded=8,
        n_frontend_embeds=2880,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=5000000.0,
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64, n_frontend_embeds=16,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=4, n_kv_heads_padded=2,
    )
