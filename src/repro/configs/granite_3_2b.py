"""granite-3-2b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base]"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=49155,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
