"""stablelm-3b [dense] — MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b]"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304, d_head=80,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=10000.0,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
