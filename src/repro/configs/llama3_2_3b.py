"""llama3.2-3b [dense] — small llama3, GQA. [hf:meta-llama/Llama-3.2-1B]

24 query heads are padded to 32 physical heads (masked) so the head axis is
divisible by the 16-wide model mesh axis; logical math is unchanged.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, d_head=128,
        n_heads_padded=32, n_kv_heads_padded=8,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-1B",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=3, n_kv_heads=1,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=4, n_kv_heads_padded=1,
    )
