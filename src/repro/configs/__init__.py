"""Architecture registry: the 10 assigned architectures + paper models."""
from __future__ import annotations

from importlib import import_module

from repro.models import ModelConfig

# arch id -> module name
ARCHS = {
    "granite-3-2b": "granite_3_2b",
    "llama3.2-3b": "llama3_2_3b",
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mixtral-8x22b": "mixtral_8x22b",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "stablelm-3b": "stablelm_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "smollm-360m": "smollm_360m",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown architecture {arch!r}; known: {sorted(ARCHS)}")
    mod = import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.reduced_config() if reduced else mod.config()


def all_archs():
    return list(ARCHS)


def paper_model(name: str, **kw):
    """The paper's own evaluation models (Section 5.1)."""
    from repro.models import ConvNet, KWTModel, LSTMModel
    builders = {
        "shakespeare-lstm": lambda: LSTMModel(**kw),
        "kwt1": lambda: KWTModel(**kw),
        "convnet": lambda: ConvNet(**kw),
    }
    return builders[name]()
