"""kimi-k2-1t-a32b [moe] — trillion-param MoE: 384 experts, top-8, one
shared expert, moe_ff=2048. [arXiv:2501.kimi2 — paper-table entry]

Experts are expert-parallel over the 16-wide model axis (24 experts/rank)
with the expert hidden additionally FSDP-sharded over the data axis.
d_head = 7168/64 = 112.
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab=163840, d_head=112,
        n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
        capacity_factor=1.25,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=1000000.0,
        source="arXiv:2501.kimi2",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, vocab_padded=0, d_head=64,
        n_experts=4, top_k=2, moe_d_ff=256, n_shared_experts=1,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
