"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]

Expert FFNs are tensor-parallel on the hidden dim (8 experts do not divide
the 16-wide model axis, so expert-parallelism is not used for this arch —
see repro.sharding).
"""
import dataclasses

import jax.numpy as jnp

from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=32768, d_head=128,
        n_experts=8, top_k=2, moe_d_ff=16384,
        attn_variant="swa", window=4096,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
        rope_theta=1000000.0,
        source="arXiv:2401.04088",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=512, vocab_padded=0, d_head=64,
        n_experts=4, top_k=2, moe_d_ff=512, window=64,
        dtype=jnp.float32, param_dtype=jnp.float32,
        n_heads_padded=0, n_kv_heads_padded=0,
    )
