from .specs import (STRATEGIES, batch_specs, cache_specs, leaf_spec,
                    make_abstract_mesh, param_specs, tree_shardings)

__all__ = ["STRATEGIES", "batch_specs", "cache_specs", "leaf_spec",
           "make_abstract_mesh", "param_specs", "tree_shardings"]
