from .specs import (STRATEGIES, batch_specs, cache_specs, leaf_spec,
                    param_specs, tree_shardings)

__all__ = ["STRATEGIES", "batch_specs", "cache_specs", "leaf_spec",
           "param_specs", "tree_shardings"]
