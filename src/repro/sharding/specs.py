"""Partition specs for parameters, optimizer state, and step inputs.

Strategy (baseline, "tp_fsdp"):
  * tensor-parallel over the ``model`` axis: attention heads, FFN hidden,
    MoE experts (expert-parallel when E divides the axis, otherwise the
    expert hidden dim is tensor-parallel — e.g. Mixtral's 8 experts on a
    16-wide axis), vocab/lm-head;
  * FSDP (ZeRO-3 style) over the ``data`` axis on a second dimension of
    every large tensor — gradients reduce-scatter, params all-gather, as
    produced by GSPMD from these specs;
  * the ``pod`` axis (multi-pod mesh) extends data parallelism.

Every rule is divisibility-guarded: if a dim does not divide the axis, the
next alternative dim is tried, else the axis is dropped (replicated). This
keeps all 10 heterogeneous architectures lowering with one rule set.

A variant registry (``STRATEGIES``) carries the hillclimb alternatives
(§Perf): e.g. "tp_only" (no FSDP), "fsdp_only", "2d_ffn".

jax-version compat policy: abstract meshes are built via
:func:`make_abstract_mesh`, which papers over the ``AbstractMesh``
constructor change between jax 0.4.x ((name, size) pairs) and newer
releases ((sizes, names) tuples). Don't call the constructor directly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P


def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]) -> AbstractMesh:
    """Build an ``AbstractMesh`` on any supported jax version.

    jax-version compat policy: jax <= 0.4.x constructs ``AbstractMesh``
    from a tuple of ``(name, size)`` pairs, newer jax from
    ``(axis_sizes, axis_names)``. Tests and sharding code must go through
    this helper instead of calling the constructor directly.
    """
    assert len(axis_sizes) == len(axis_names)
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def _axis_size(mesh, name: str) -> int:
    # works for Mesh and AbstractMesh alike
    return dict(mesh.shape).get(name, 1)


def _data_axes(mesh: Mesh):
    """data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# rule table: leaf-name (+ndim) -> list of (dim, axis-role) preferences.
# axis-role: "model" = TP axis, "data" = FSDP axis. dim indices refer to the
# STACKED tensor (leading L axis for block params). Alternatives for the
# same role are tried left to right.
def _rules(name: str, ndim: int, parent: str) -> List[Tuple[str, List[int]]]:
    r: List[Tuple[str, List[int]]] = []
    if name == "embed":
        return [("model", [0]), ("data", [1])]
    if name == "lm_head":
        return [("model", [1, 0]), ("data", [0])]
    if parent in ("attn", "xattn"):
        if name == "wq":
            return [("model", [2]), ("data", [1])]
        if name in ("wk", "wv"):
            return [("model", [2]), ("data", [1])]
        if name == "wo":
            return [("model", [1]), ("data", [3])]
    if parent == "moe":
        if name == "router":
            return [("data", [1])]
        if name in ("w1", "w3"):       # [L, E, d, f]
            return [("model", [1, 3]), ("data", [2])]
        if name == "w2":               # [L, E, f, d]
            return [("model", [1, 2]), ("data", [3])]
        if name in ("shared_w1", "shared_w3"):
            return [("model", [2]), ("data", [1])]
        if name == "shared_w2":
            return [("model", [1]), ("data", [2])]
    if parent == "ffn" or (parent == "cm" and name in ("wk", "wv")):
        if name in ("w1", "w3", "wk"):  # [L, d, f]
            return [("model", [2]), ("data", [1])]
        if name in ("w2", "wv"):        # [L, f, d]
            return [("model", [1]), ("data", [2])]
    if parent == "tm":  # rwkv time mix
        if name in ("wr", "wk", "wv", "wg"):
            return [("model", [2]), ("data", [1])]
        if name == "wo":
            return [("model", [1]), ("data", [2])]
        if name in ("shift_lora_a", "w_lora_a"):
            return [("data", [1])]
        if name == "shift_lora_b":
            return [("data", [3])]
        if name == "w_lora_b":
            return [("data", [2])]
    if parent == "mamba":
        if name in ("in_proj", "w_bc"):
            return [("data", [1])]
        if name in ("out_proj",):
            return [("data", [2])]
    if parent in ("cells",):  # LSTM — replicated
        return []
    return []  # norms, scalars, small vectors: replicated


def leaf_spec(path, leaf, mesh: Mesh, fsdp: bool = True,
              tp: bool = True, fsdp_in_pod: bool = False) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    shape = leaf.shape
    assign: Dict[int, object] = {}
    data_axes = _data_axes(mesh)
    if fsdp_in_pod:
        # keep the ZeRO-3 gather inside a pod: params replicated across the
        # (slower, inter-pod) 'pod' axis, sharded over 'data' only
        data_axes = tuple(a for a in data_axes if a != "pod")
    data_sz = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
    model_sz = _axis_size(mesh, "model")
    for role, dims in _rules(name, len(shape), parent):
        if role == "model" and not tp:
            continue
        if role == "data" and not fsdp:
            continue
        size = model_sz if role == "model" else data_sz
        axis_val = "model" if role == "model" else (
            data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None))
        if size <= 1 or axis_val is None:
            continue
        for d in dims:
            if d in assign:
                continue
            if shape[d] % size == 0:
                assign[d] = axis_val
                break
    spec = [assign.get(d) for d in range(len(shape))]
    return P(*spec)


def param_specs(params_struct, mesh: Mesh, fsdp: bool = True, tp: bool = True,
                fsdp_in_pod: bool = False, **_ignored):
    """Pytree of PartitionSpec matching ``params_struct`` (works for params
    and for optimizer state, whose subtrees mirror parameter paths)."""
    flat = jax.tree_util.tree_flatten_with_path(params_struct)[0]
    specs = [leaf_spec(path, leaf, mesh, fsdp, tp, fsdp_in_pod)
             for path, leaf in flat]
    treedef = jax.tree_util.tree_structure(params_struct)
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# step-input shardings


def batch_specs(batch_struct, mesh: Mesh):
    """Training batch: shard the leading (global batch) dim over pod+data."""
    data_axes = _data_axes(mesh)
    ax = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def one(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        sz = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
        if leaf.ndim and sz > 1 and b % sz == 0:
            return P(ax, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    flat = jax.tree_util.tree_flatten_with_path(batch_struct)[0]
    specs = [one(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(batch_struct), specs)


def cache_specs(cache_struct, mesh: Mesh, seq_over_model: bool = False):
    """Decode cache: batch dim over pod+data when divisible, else the
    sequence/window dim (long-context batch=1); KV heads replicated.

    ``seq_over_model=True`` additionally shards the cache sequence dim over
    the model axis (flash-decode style partial attention + psum) — the
    hillclimb variant that makes the 1T-param decode shapes fit HBM."""
    data_axes = _data_axes(mesh)
    ax = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    sz = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
    model_sz = _axis_size(mesh, "model")

    def one(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        spec = [None] * leaf.ndim
        if sz <= 1 or ax is None or leaf.ndim < 2:
            return P(*spec)
        # stacked caches: dim0 = L (or scalar length), dim1 = batch
        b_dim = 1
        if leaf.ndim > b_dim and leaf.shape[b_dim] % sz == 0:
            spec[b_dim] = ax
            if (seq_over_model and leaf.ndim >= 3 and model_sz > 1
                    and leaf.shape[2] % model_sz == 0 and leaf.shape[2] >= 1024):
                spec[2] = "model"
        elif leaf.ndim >= 3 and leaf.shape[2] % sz == 0:
            spec[2] = ax  # sequence/window dim
        return P(*spec)

    flat = jax.tree_util.tree_flatten_with_path(cache_struct)[0]
    specs = [one(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_struct), specs)


def tree_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


STRATEGIES = {
    # baseline
    "tp_fsdp": dict(fsdp=True, tp=True),
    # hillclimb variants (§Perf)
    "tp_only": dict(fsdp=False, tp=True),          # params resident (decode)
    "fsdp_only": dict(fsdp=True, tp=False),
    "tp_fsdp_inpod": dict(fsdp=True, tp=True, fsdp_in_pod=True),
    "tp_fsdp_seqkv": dict(fsdp=True, tp=True, seq_over_model=True),
    "tp_only_seqkv": dict(fsdp=False, tp=True, seq_over_model=True),
    "tp_fsdp_flatkv": dict(fsdp=True, tp=True, seq_over_model=False),
}
