"""CI gate: fail unless the test run actually collects hypothesis tests.

The property suites import hypothesis behind a try/except and fall back
to seeded sweeps when it is missing — correct for minimal environments,
but it means a CI image that silently drops the dependency would run
the fallbacks forever and nobody would notice. This tool collects the
test tree (no execution) and counts items whose underlying function
hypothesis has wrapped (the ``is_hypothesis_test`` attribute its
``@given`` decorator sets), then fails below ``--min``.

    PYTHONPATH=src python tools/check_hypothesis_collected.py --min 1 tests

Exit codes: 0 ok, 1 hypothesis missing / too few property tests /
collection error.
"""
from __future__ import annotations

import argparse
import sys
from collections import Counter


class _CollectionCounter:
    """Pytest plugin: record nodeids of hypothesis-wrapped test items."""

    def __init__(self):
        self.hypothesis_items: list = []
        self.total = 0

    def pytest_collection_finish(self, session):
        for item in session.items:
            self.total += 1
            fn = getattr(item, "obj", None)
            if getattr(fn, "is_hypothesis_test", False):
                self.hypothesis_items.append(item.nodeid)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["tests"])
    ap.add_argument("--min", type=int, default=1,
                    help="minimum hypothesis-driven tests required")
    args = ap.parse_args(argv)

    try:
        import hypothesis  # noqa: F401
    except ImportError:
        print("FAIL: hypothesis is not importable — the property suites "
              "would run their seeded fallbacks only")
        return 1
    import pytest

    counter = _CollectionCounter()
    rc = pytest.main(["--collect-only", "-q", "-p", "no:cacheprovider",
                      *args.paths], plugins=[counter])
    if rc not in (0,):
        print(f"FAIL: pytest collection exited {rc}")
        return 1
    by_module = Counter(nid.split("::")[0]
                        for nid in counter.hypothesis_items)
    for mod, n in sorted(by_module.items()):
        print(f"{mod}: {n} hypothesis test(s)")
    n_hyp = len(counter.hypothesis_items)
    print(f"collected {counter.total} tests, {n_hyp} hypothesis-driven")
    if n_hyp < args.min:
        print(f"FAIL: {n_hyp} < --min {args.min} — hypothesis installed "
              "but the property suites are not using it")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
