"""Render the README's reproduced-results tables from the committed
benchmark JSONs.

    python tools/bench_table.py            # print the markdown
    python tools/bench_table.py --write    # splice it into README.md

``--write`` replaces everything between the ``<!-- bench-tables:begin
-->`` / ``<!-- bench-tables:end -->`` markers, so the README never
hand-maintains numbers — rerun it whenever the BENCH_*.json files are
regenerated.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
BEGIN, END = "<!-- bench-tables:begin -->", "<!-- bench-tables:end -->"


def _load(name):
    with open(os.path.join(ROOT, name)) as f:
        return json.load(f)


def e2e_table() -> str:
    payload = _load("BENCH_e2e_simulation.json")
    lines = [
        "| Config | Clients | Simulated | Wall | ms/round | Peak RSS "
        "| Rounds | Gates |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, row in payload["configs"].items():
        if row.get("kind") == "registry":
            sim = "registry build"
            rounds = mspr = "—"
        else:
            d = row["sim_days"]
            sim = f"{d} day{'s' if d != 1 else ''}" \
                  + (" (sparse)" if row.get("util_mode") == "sparse" else "")
            if row.get("backend", "numpy") != "numpy":
                sim += f", `{row['backend']}`"
            rounds = str(row["rounds"])
            mspr = f"{row['ms_per_round']:.0f}" \
                if row.get("ms_per_round") else "—"
            ratio = row.get("ms_per_round_vs_numpy")
            if ratio:
                mspr += f" ({ratio:.2f}× numpy)"
        rss = row.get("peak_rss_mb")
        rss = f"{rss/1024:.2f} GB" if rss == rss else "n/a"
        lines.append(
            f"| `{key}` | {row['n_clients']:,} | {sim} "
            f"| {row['wall_s']:.1f} s | {mspr} | {rss} | {rounds} "
            f"| {'pass' if row.get('ok') else 'FAIL'} |")
    return "\n".join(lines)


def scalability_table() -> str:
    payload = _load("BENCH_scalability.json")
    lines = [
        "| `select_clients` (greedy) | Wall |",
        "|---|---|",
    ]
    for row in payload["selection_greedy"]:
        lines.append(f"| {row['n_clients']:,} clients "
                     f"| {row['wall_s']*1000:.0f} ms |")
    return "\n".join(lines)


def service_table() -> str:
    payload = _load("BENCH_service.json")
    lines = [
        "| Config | Clients | Workload / step | Decisions/s | p50 | p99 "
        "| Peak RSS | Gates |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, row in payload["configs"].items():
        mix = (f"{row['churn']*100:.0f}% churn, {row['admits_per_step']} "
               f"admit + {row['quotes_per_step']} quote")
        if row.get("faults"):
            mix += (f", {row.get('executor', '?')} x{row.get('workers', 0)}"
                    f" + faults")
        rss = row.get("peak_rss_mb")
        rss = f"{rss/1024:.2f} GB" if rss == rss else "n/a"
        lines.append(
            f"| `{key}` | {row['n_clients']:,} | {mix} "
            f"| {row['decisions_per_sec']:.0f} | {row['p50_ms']:.1f} ms "
            f"| {row['p99_ms']:.1f} ms | {rss} "
            f"| {'pass' if row.get('ok') else 'FAIL'} |")
    return "\n".join(lines)


def render() -> str:
    return (f"End-to-end FedZero loop (`BENCH_e2e_simulation.json`):\n\n"
            f"{e2e_table()}\n\nOne `select_clients` call "
            f"(`BENCH_scalability.json`):\n\n{scalability_table()}"
            f"\n\nAlways-on scheduling service under churn "
            f"(`BENCH_service.json`, docs/service.md):\n\n{service_table()}")


def main():
    text = render()
    if "--write" in sys.argv[1:]:
        path = os.path.join(ROOT, "README.md")
        with open(path) as f:
            readme = f.read()
        head, _, rest = readme.partition(BEGIN)
        _, _, tail = rest.partition(END)
        with open(path, "w") as f:
            f.write(f"{head}{BEGIN}\n{text}\n{END}{tail}")
        print(f"wrote tables into {os.path.abspath(path)}")
    else:
        print(text)


if __name__ == "__main__":
    main()
