"""Executable-docs gate: run every fenced Python snippet and validate
intra-repo links in the markdown docs.

    python tools/docs_check.py            # what `make docs-check` runs

Scope: README.md plus every .md under docs/. Two checks:

1. **Snippets execute.** Each fenced ``` ```python ``` block runs in its
   own namespace via ``exec`` with src/ on sys.path — documentation that
   drifts from the API fails CI exactly like a test would. A block whose
   info string is ``python no-run`` is illustrative-only and skipped.
2. **Links resolve.** Every relative markdown link target
   (``[text](path)`` — external ``http(s):``/``mailto:`` links are
   ignored) must exist on disk, anchors stripped.

Exit code 0 iff every snippet executed and every link resolved.
"""
from __future__ import annotations

import os
import re
import sys
import traceback

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))

_FENCE = re.compile(r"^```(\S*)[ \t]*(\S*)[ \t]*$")
_LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")


def doc_files():
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def extract_snippets(path):
    """[(first_line_number, source)] for runnable python fences.

    Raises on an unclosed fence at EOF — a silently-dropped trailing
    snippet would let the gate pass without running documented code.
    """
    snippets, lang, run, buf, start = [], None, False, [], 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            m = _FENCE.match(line.strip())
            if m and lang is None:
                lang, info = m.group(1).lower(), m.group(2).lower()
                run = lang == "python" and info != "no-run"
                buf, start = [], lineno + 1
            elif m and m.group(1) == "":
                if run and buf:
                    snippets.append((start, "".join(buf)))
                lang, run, buf = None, False, []
            elif lang is not None:
                buf.append(line)
    if lang is not None:
        raise SyntaxError(f"{path}: code fence opened at line {start - 1} "
                          f"is never closed")
    return snippets


def check_snippets(path) -> int:
    failures = 0
    rel = os.path.relpath(path, ROOT)
    for lineno, src in extract_snippets(path):
        try:
            exec(compile(src, f"{rel}:{lineno}", "exec"), {"__name__": "__docs__"})
        except Exception:
            failures += 1
            print(f"[docs-check] SNIPPET FAILED {rel}:{lineno}")
            traceback.print_exc()
    return failures


def check_links(path) -> int:
    failures = 0
    rel = os.path.relpath(path, ROOT)
    base = os.path.dirname(path)
    with open(path) as f:
        text = f.read()
    # don't validate link-shaped text inside code fences
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target_path = os.path.normpath(
            os.path.join(base, target.split("#")[0]))
        if not os.path.exists(target_path):
            failures += 1
            print(f"[docs-check] BROKEN LINK {rel}: {target}")
    return failures


def main() -> int:
    files = doc_files()
    failures = 0
    n_snippets = 0
    for path in files:
        n_snippets += len(extract_snippets(path))
        failures += check_snippets(path)
        failures += check_links(path)
    status = "ok" if failures == 0 else f"{failures} failure(s)"
    print(f"[docs-check] {len(files)} file(s), {n_snippets} snippet(s): "
          f"{status}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
